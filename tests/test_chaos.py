"""Fault-tolerant cluster runtime (DESIGN.md §14) under deterministic
fault injection (`repro.persist.faults`):

  * a worker whose durability path dies mid-stream (background commit
    crash -> fail-stop poison) is rebuilt and `recover()`ed in place by
    the coordinator, and the cluster's final answers are bit-identical to
    a never-faulted cluster — for all three sketches;
  * an *unrecoverable* worker is declared DEAD and its replayable WAL
    tail is re-partitioned to the survivors through the merge algebra:
    RACE stays bit-identical to a single engine over the whole stream
    (counter sums are exact under any routing);
  * degraded-query policies: ``fail`` raises while any worker is DEAD,
    ``partial`` serves the live subset with ``worker_coverage < 1`` on
    every merge, ``block`` serves once the data is whole (every dead
    worker fully salvaged) and raises at the deadline otherwise;
  * transient faults (`InjectedIOError(transient=True)`) retry in place
    with backoff — no recovery, no death, identical state;
  * seeded chaos soak (the CI ``chaos`` job): a `seeded_plan` kills every
    worker's durability path at least once mid-stream; ingest + queries
    must converge with zero bit-identity violations and zero hung
    threads, and the fault-site coverage report is written as an
    artifact when ``REPRO_CHAOS_REPORT`` is set.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro import persist
from repro.persist import faults
from repro.serve.cluster import (
    ClusterDegradedError, ClusterKDEService, ClusterRACEService,
    ClusterRetrievalService, FailoverConfig, hash_partition,
)
from repro.serve.kde_service import KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig

_RACE_KW = dict(dim=8, L=6, W=32, ingest_chunk=64, seed=3)
_KDE_KW = dict(dim=8, L=6, W=32, window=100_000, eh_eps=0.2, ingest_chunk=50)
_SANN_KW = dict(dim=8, n_max=100, eta=0.0, r=0.4, c=2.0, w=1.0, L=6, k=3,
                ingest_chunk=64)

# Fast-failing failover for tests: one rebuild attempt, ~no backoff.
_FO = dict(max_retries=1, backoff_s=0.001)


def _data(n=500, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


def _states_equal(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(la, lb))


def _clusters(tmp_path, name, kw_extra=()):
    """(make, query) factories per sketch for the recovery tests."""
    if name == "race":
        def make(sub, failover):
            return ClusterRACEService(
                RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path
                                                               / sub)),
                num_workers=2, merge_every=4, failover=failover)
    elif name == "kde":
        def make(sub, failover):
            return ClusterKDEService(
                KDEServiceConfig(**_KDE_KW, snapshot_dir=str(tmp_path
                                                             / sub)),
                num_workers=2, merge_every=4, failover=failover)
    else:
        def make(sub, failover):
            return ClusterRetrievalService(
                RetrievalConfig(**_SANN_KW, snapshot_dir=str(tmp_path
                                                             / sub)),
                num_workers=2, merge_every=4, failover=failover)
    return make


# ---------------------------------------------------------------------------
# In-place worker recovery: bit-identity per sketch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["race", "kde", "sann"])
def test_worker_commit_crash_auto_recovers_bit_identical(tmp_path, name):
    """Kill worker 1's commit path mid-stream; the coordinator rebuilds it
    from snapshot + WAL tail (bit-identical recovery) and the cluster
    converges to exactly the never-faulted cluster's merged state."""
    make = _clusters(tmp_path, name)
    data = _data(seed=21)
    ref = make("ref", None)
    ref.ingest(data)

    svc = make("svc", FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_1/engine.commit", mode="crash", hit=2)])
    with faults.installed(plan):
        for i in range(0, len(data), 100):
            svc.ingest(data[i:i + 100])
    assert plan.fired, "the injected commit crash never fired"
    h = svc.health()
    assert h["counters"]["recoveries"] >= 1
    assert h["dead_workers"] == [] and h["coverage"] == 1.0
    assert [wh["health"] for wh in h["workers"]] == ["live", "live"]
    assert _states_equal(svc.merged_state(), ref.merged_state())
    svc.close()
    ref.close()


def test_torn_wal_tail_on_worker_auto_recovers(tmp_path):
    """A torn WAL append on a worker (process death mid-write) poisons it;
    failover truncates the torn tail during recover() and the cluster
    still converges bit-identically (the torn chunk was never accepted,
    and the coordinator resubmits exactly it)."""
    data = _data(seed=22)
    ref = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "ref")),
        num_workers=2, merge_every=4)
    ref.ingest(data)

    svc = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "svc")),
        num_workers=2, merge_every=4, failover=FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_0/wal.append", mode="torn_tail", hit=2)])
    with faults.installed(plan):
        for i in range(0, len(data), 100):
            svc.ingest(data[i:i + 100])
    assert plan.fired
    assert svc.health()["counters"]["recoveries"] >= 1
    assert _states_equal(svc.merged_state(), ref.merged_state())
    svc.close()
    ref.close()


# ---------------------------------------------------------------------------
# Unrecoverable worker: WAL-tail re-partition + degraded-mode queries
# ---------------------------------------------------------------------------

def _kill_worker_dead(tmp_path, on_degraded="partial", repartition=True,
                      sub="svc"):
    """RACE K=3 cluster; worker 1 dies unrecoverably (its recover() is
    also fault-killed) mid-stream.  Huge snapshot cadence -> nothing
    compacted -> the whole history is salvageable."""
    data = _data(n=600, seed=23)
    svc = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / sub),
                          snapshot_every=10_000),
        num_workers=3, merge_every=4,
        failover=FailoverConfig(on_degraded=on_degraded,
                                block_deadline_s=0.2,
                                repartition=repartition, **_FO))
    plan = persist.FaultPlan([
        persist.FaultSpec(site="worker_1/engine.commit", mode="crash",
                          hit=2),
        persist.FaultSpec(site="worker_1/engine.recover", mode="crash",
                          hit=1, count=99),
    ])
    with faults.installed(plan):
        for i in range(0, len(data), 100):
            svc.ingest(data[i:i + 100])
    assert plan.hits.get("worker_1/engine.recover"), \
        "worker 1 was never declared unrecoverable"
    return svc, data


def test_dead_worker_wal_tail_repartitions_exactly(tmp_path):
    svc, data = _kill_worker_dead(tmp_path)
    h = svc.health()
    assert h["dead_workers"] == [1]
    assert h["salvage_complete"] == [1], "full WAL should salvage cleanly"
    assert h["epoch"] >= 1 and h["counters"]["repartitions"] == 1
    assert h["counters"]["salvaged_rows"] > 0
    assert 0 < svc.coverage == pytest.approx(2 / 3)

    # RACE counter sums are exact under ANY routing: the re-partitioned
    # cluster is bit-identical to one engine fed the whole stream.
    single = RACEService(RACEServiceConfig(**_RACE_KW))
    single.ingest(data)
    assert _states_equal(svc.merged_state(), single.state)
    assert svc.count == single.count == len(data)

    # partial answers always carry coverage < 1 while a worker is DEAD
    qs = data[:5] + 0.01
    np.testing.assert_array_equal(svc.query(qs), single.query(qs))
    _, meta, _ = svc.merged_snapshot()
    assert meta["worker_coverage"] == pytest.approx(2 / 3)
    assert meta["workers_live"] == 2 and meta["workers_total"] == 3

    # post-death ingest routes around the dead worker and stays exact
    more = _data(n=100, seed=24)
    svc.ingest(more)
    single.ingest(more)
    assert _states_equal(svc.merged_state(), single.state)
    svc.close()
    single.close()


def test_degraded_policy_fail_raises_while_dead(tmp_path):
    svc, data = _kill_worker_dead(tmp_path, on_degraded="fail")
    with pytest.raises(ClusterDegradedError) as ei:
        svc.query(data[:3])
    assert ei.value.dead == [1] and ei.value.salvaged == [1]
    svc.close()


def test_degraded_policy_block_serves_when_whole_raises_when_not(tmp_path):
    # Fully salvaged -> the data is whole -> block serves immediately.
    svc, data = _kill_worker_dead(tmp_path, on_degraded="block", sub="a")
    assert svc.query(data[:3]).shape == (3,)
    svc.close()
    # repartition off -> the dead worker's tail is lost -> block times out.
    svc2, data = _kill_worker_dead(tmp_path, on_degraded="block",
                                   repartition=False, sub="b")
    with pytest.raises(ClusterDegradedError, match="not fully"):
        svc2.query(data[:3])
    svc2.close()


def test_dead_worker_pins_in_cluster_meta_across_reopen(tmp_path):
    svc, data = _kill_worker_dead(tmp_path)
    state = svc.merged_state()
    svc.close()
    meta = json.loads((tmp_path / "svc" / "cluster.json").read_text())
    assert meta["dead_workers"] == [1] and meta["epoch"] >= 1

    re = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "svc"),
                          snapshot_every=10_000),
        num_workers=3, merge_every=4,
        failover=FailoverConfig(on_degraded="partial", **_FO))
    re.recover()                       # dead worker skipped, survivors only
    assert re.health()["dead_workers"] == [1]
    assert _states_equal(re.merged_state(), state)
    re.close()


# ---------------------------------------------------------------------------
# Transient faults: in-place retry, no failover
# ---------------------------------------------------------------------------

def test_transient_wal_fault_retries_in_place(tmp_path):
    data = _data(seed=25)
    ref = ClusterRACEService(RACEServiceConfig(**_RACE_KW), num_workers=2,
                             merge_every=4)
    ref.ingest(data)
    svc = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path)),
        num_workers=2, merge_every=4, failover=FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_0/wal.append", mode="io_error", transient=True,
        hit=2)])
    with faults.installed(plan):
        svc.ingest(data)
    assert plan.fired
    h = svc.health()
    assert h["counters"]["retries"] >= 1
    assert h["counters"]["recoveries"] == 0 and h["dead_workers"] == []
    assert _states_equal(svc.merged_state(), ref.merged_state())
    svc.close()
    ref.close()


def test_transient_merge_fault_retries(tmp_path):
    data = _data(n=200, seed=26)
    svc = ClusterRACEService(RACEServiceConfig(**_RACE_KW), num_workers=2,
                             merge_every=4, failover=FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="cluster.merge", mode="io_error", transient=True)])
    with faults.installed(plan):
        svc.ingest(data)
        out = svc.query(data[:3])
    assert plan.fired and out.shape == (3,)
    assert svc.health()["counters"]["retries"] >= 1
    svc.close()


# ---------------------------------------------------------------------------
# Seeded chaos soak (the CI `chaos` job)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_seeded_chaos_soak(tmp_path, seed):
    """One seeded plan per run kills every worker's durability path at
    least once mid-stream (`faults.seeded_plan` emits one fault per
    worker scope).  The cluster must absorb all of it — in-place
    recoveries, or death + full-WAL re-partition — with RACE's final
    answers bit-identical to a single engine over the whole stream, and
    no thread leaked.  Writes the fault-site coverage report when
    ``REPRO_CHAOS_REPORT`` is set (uploaded as a CI artifact)."""
    K = 3
    threads_before = threading.active_count()
    data = _data(n=900, seed=100 + seed)
    # Kill-sites only (snapshot.save never fires under the huge snapshot
    # cadence this test uses to keep salvage whole, and `delay` doesn't
    # kill): every worker draws a crash or a torn WAL tail.
    plan = faults.seeded_plan(
        seed, scopes=[f"worker_{w}/" for w in range(K)],
        sites=("engine.commit", "wal.append"),
        modes=("crash", "torn_tail"))
    svc = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path),
                          snapshot_every=10_000),
        num_workers=K, merge_every=4,
        failover=FailoverConfig(on_degraded="partial", **_FO))
    with faults.installed(plan):
        for i in range(0, len(data), 100):
            svc.ingest(data[i:i + 100])
            svc.query(data[i:i + 3])       # queries during the storm
    assert plan.fired, f"seed {seed}: no fault fired (dead soak)"
    killed = {f["site"].split("/")[0] for f in plan.fired}
    assert killed == {f"worker_{w}" for w in range(K)}, (
        f"seed {seed}: not every worker was killed: {sorted(killed)}")

    single = RACEService(RACEServiceConfig(**_RACE_KW))
    single.ingest(data)
    h = svc.health()
    identical = _states_equal(svc.merged_state(), single.state)
    # Bit-identity must hold through any mix of recoveries and complete
    # re-partitions (huge snapshot cadence -> salvage is always whole).
    assert identical, (
        f"seed {seed}: bit-identity violated; health={h}")
    assert sorted(h["dead_workers"]) == sorted(h["salvage_complete"])
    np.testing.assert_array_equal(svc.query(data[:5]),
                                  single.query(data[:5]))
    svc.close()
    single.close()
    assert threading.active_count() <= threads_before, (
        f"seed {seed}: hung threads: "
        f"{[t.name for t in threading.enumerate()]}")

    report_dir = os.environ.get("REPRO_CHAOS_REPORT")
    if report_dir:
        os.makedirs(report_dir, exist_ok=True)
        with open(os.path.join(report_dir, f"chaos_seed{seed}.json"),
                  "w") as f:
            json.dump({"seed": seed, "plan": plan.report(),
                       "health": {k: v for k, v in h.items()
                                  if k != "workers"},
                       "bit_identical": identical}, f, indent=2)


# ---------------------------------------------------------------------------
# Review regressions: op-level acceptance + crash-resumable salvage
# ---------------------------------------------------------------------------

def test_rejected_delete_after_stale_poison_is_resubmitted(tmp_path):
    """Regression: a worker poisoned by a *background commit* failure
    carries an 'accepted'-flavoured poison reason describing that earlier
    op.  A delete() arriving afterwards is rejected up front (never
    WAL-logged), so after the in-place recovery the failover layer MUST
    resubmit it — deciding from the stale poison reason used to drop the
    delete silently (lost RACE decrements)."""
    # One engine chunk per worker: submission fully completes before the
    # background commit crash can poison, so the coordinator first sees
    # the poison inside delete() (the scenario under test), never during
    # ingest_async.
    data = _data(n=100, seed=30)
    pid = hash_partition(data, 2)
    # rows owned by each worker, so worker 0's delete path is exercised
    dels = np.concatenate([data[pid == 0][:3], data[pid == 1][:3]])

    single = RACEService(RACEServiceConfig(**_RACE_KW))
    single.ingest(data)
    single.delete(dels)

    svc = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path)),
        num_workers=2, merge_every=4, failover=FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_0/engine.commit", mode="crash", hit=1)])
    with faults.installed(plan):
        svc.ingest_async(data)
        deadline = time.monotonic() + 30
        while (not svc.workers[0]._poisoned
               and time.monotonic() < deadline):
            time.sleep(0.005)
    assert plan.fired and svc.workers[0]._poisoned
    assert "accepted" in svc.workers[0]._poison_reason
    # No flush yet: the coordinator sees the poison for the first time
    # inside delete(), whose own op was rejected by _check_ingestable.
    svc.delete(dels)
    svc.flush()
    h = svc.health()
    assert h["counters"]["recoveries"] >= 1 and h["dead_workers"] == []
    assert _states_equal(svc.merged_state(), single.state)
    assert svc.count == single.count
    svc.close()
    single.close()


def test_salvage_resumes_from_checkpoint_after_coordinator_crash(tmp_path):
    """Coordinator crash mid-salvage (injected at the ``cluster.salvage``
    checkpoint site): the dead set, epoch and salvage progress are
    already pinned in cluster.json, so a reopened cluster's recover()
    resumes the re-partition *after* the durable prefix — nothing handed
    to the survivors before the crash is re-ingested (RACE stays
    bit-identical to a single engine), and the unfinished tail is
    salvaged, not lost."""
    data = _data(n=600, seed=27)
    pid = hash_partition(data[:300], 3)
    dels = data[:300][pid == 1][:4]   # a mutation record lands on w1's WAL
    cfg = RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path),
                            snapshot_every=10_000)
    fo = FailoverConfig(on_degraded="partial", **_FO)
    svc = ClusterRACEService(cfg, num_workers=3, merge_every=4, failover=fo)
    svc.ingest(data[:300])
    svc.delete(dels)
    plan = persist.FaultPlan([
        persist.FaultSpec(site="worker_1/engine.commit", mode="crash",
                          hit=1),
        persist.FaultSpec(site="worker_1/engine.recover", mode="crash",
                          hit=1, count=99),
        # Checkpoint 1 = the chunk prefix drained ahead of the delete;
        # checkpoint 2 = the re-applied delete — crash right after it.
        persist.FaultSpec(site="cluster.salvage", mode="crash", hit=2),
    ])
    with faults.installed(plan):
        for i in range(300, 600, 100):
            svc.ingest(data[i:i + 100])
    assert plan.hits.get("cluster.salvage") == 2, \
        "the mid-salvage coordinator crash never fired"
    h = svc.health()
    assert h["dead_workers"] == [1]
    assert h["salvage_complete"] == []           # left unfinished
    assert h["salvage_progress"].get(1, -1) >= 0
    svc.close()
    meta = json.loads((tmp_path / "cluster.json").read_text())
    assert meta["dead_workers"] == [1]
    assert int(meta["salvage_progress"]["1"]) >= 0

    re = ClusterRACEService(cfg, num_workers=3, merge_every=4, failover=fo)
    re.recover()                      # resumes + completes the salvage
    h = re.health()
    assert h["dead_workers"] == [1]
    assert h["salvage_complete"] == [1]
    assert h["salvage_progress"] == {}

    single = RACEService(RACEServiceConfig(**_RACE_KW))
    single.ingest(data)
    single.delete(dels)
    assert _states_equal(re.merged_state(), single.state)
    assert re.count == single.count == len(data) - len(dels)
    re.close()
    single.close()
