"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, strategies as st

from repro.kernels import cand_score as cs_k
from repro.kernels import ingest_commit as ic_k
from repro.kernels import race_update as ru_k
from repro.kernels import ref
from repro.kernels import sketch_decode_attn as sda_k
from repro.kernels import srp_hash as sh_k


# ---------------------------------------------------------------------------
# srp_hash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 7, 128, 300])
@pytest.mark.parametrize("d", [32, 128])
@pytest.mark.parametrize("Lk", [(4, 4), (8, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_srp_hash_matches_ref(B, d, Lk, dtype):
    L, k = Lk
    key = jax.random.PRNGKey(B * d + L)
    x = jax.random.normal(key, (B, d), dtype)
    proj = jax.random.normal(jax.random.PRNGKey(1), (d, L * k), jnp.float32)
    mix = jax.random.randint(jax.random.PRNGKey(2), (L, k), 1, 2**30).astype(jnp.uint32) | 1
    got = sh_k.srp_hash(x, proj, mix, n_buckets=97, interpret=True)
    want = ref.srp_hash_ref(x, proj, mix, n_buckets=97)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(B=st.integers(1, 64), d=st.sampled_from([16, 64]), L=st.integers(1, 6))
def test_srp_hash_property(B, d, L):
    k = 3
    x = jax.random.normal(jax.random.PRNGKey(B), (B, d))
    proj = jax.random.normal(jax.random.PRNGKey(d), (d, L * k))
    mix = jax.random.randint(jax.random.PRNGKey(L), (L, k), 1, 2**30).astype(jnp.uint32) | 1
    got = sh_k.srp_hash(x, proj, mix, n_buckets=64, interpret=True)
    want = ref.srp_hash_ref(x, proj, mix, n_buckets=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# race_hist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 37, 300])
@pytest.mark.parametrize("L", [1, 4])
@pytest.mark.parametrize("W", [64, 128])
def test_race_hist_matches_ref(B, L, W):
    codes = jax.random.randint(jax.random.PRNGKey(B + L + W), (B, L), 0, W, jnp.int32)
    got = ru_k.race_hist(codes, W, interpret=True)
    want = ref.race_update_ref(jnp.zeros((L, W), jnp.int32), codes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() == B * L


# ---------------------------------------------------------------------------
# cand_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M", [1, 24, 256, 777])
@pytest.mark.parametrize("d", [8, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cand_score_matches_ref(M, d, dtype):
    q = jax.random.normal(jax.random.PRNGKey(M), (d,), dtype)
    c = jax.random.normal(jax.random.PRNGKey(d), (M, d), dtype)
    got = cs_k.cand_score(q, c, interpret=True)
    want = ref.cand_score_ref(q, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=1e-4)


# ---------------------------------------------------------------------------
# sketch_decode_attn
# ---------------------------------------------------------------------------

def _attn_case(seed, Hkv, G, dh, S, bs, softcap, frac_live, kv_len):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (Hkv, G, dh), jnp.float32)
    k = jax.random.normal(ks[1], (S, Hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (S, Hkv, dh), jnp.float32)
    nb = S // bs
    live = jax.random.uniform(ks[3], (nb,)) < frac_live
    ids = np.full((nb,), -1, np.int32)
    lv = np.where(np.asarray(live))[0]
    ids[: len(lv)] = lv
    block_ids = jnp.asarray(ids)
    n_live = jnp.asarray([len(lv)], jnp.int32)
    kvl = jnp.asarray([kv_len], jnp.int32)

    got = sda_k.sketch_decode_attn(
        q, k, v, block_ids, n_live, kvl, block_size=bs, softcap=softcap,
        interpret=True)
    want = ref.sketch_decode_attn_ref(q, k, v, live, kvl[0], bs, softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("frac_live", [1.0, 0.5])
def test_sketch_decode_attn_matches_ref(softcap, frac_live):
    _attn_case(0, Hkv=2, G=4, dh=64, S=1024, bs=128, softcap=softcap,
               frac_live=frac_live, kv_len=900)


def test_sketch_decode_attn_partial_kv():
    _attn_case(1, Hkv=1, G=8, dh=32, S=512, bs=64, softcap=0.0,
               frac_live=1.0, kv_len=100)


def test_sketch_decode_attn_no_live_blocks():
    """All blocks pruned → zero output (matches oracle's nan→0)."""
    _attn_case(2, Hkv=1, G=2, dh=32, S=256, bs=64, softcap=0.0,
               frac_live=0.0, kv_len=256)


# ---------------------------------------------------------------------------
# ingest_commit (segment-reduce SumEH commit + S-ANN table scatter)
# ---------------------------------------------------------------------------

def _segment_case(seed, R=3, G=11, LV=6, S=5, C=64, window=37):
    rng = np.random.default_rng(seed)
    base_t = 1000
    cell_num = rng.integers(0, S, (R, G, LV)).astype(np.int32)
    cell_ts = (base_t - rng.integers(0, window, (R, G, LV, S))).astype(np.int32)
    sorted_ts = np.sort(
        rng.integers(base_t, base_t + 50, (R, C)), axis=1).astype(np.int32)
    seg_first = np.zeros((R, G), np.int32)
    seg_len = np.zeros((R, G), np.int32)
    for r in range(R):
        cuts = np.sort(rng.choice(np.arange(1, C), size=G - 1, replace=False))
        bounds = np.concatenate([[0], cuts, [C]])
        seg_first[r] = bounds[:G]
        seg_len[r] = np.diff(bounds)[:G]
    done = np.minimum(rng.integers(0, 3, (R, G)), seg_len).astype(np.int32)
    return tuple(jnp.asarray(a) for a in (
        cell_ts, cell_num, done, sorted_ts, seg_first, seg_len)), window


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("cap", [0, 2])
@pytest.mark.parametrize("block_g", [4, 8, 16])
def test_swakde_segment_pass_matches_ref(seed, cap, block_g):
    """Tiled kernel == oracle bit-for-bit, including non-divisible segment
    grids (padding segments are empty → identity)."""
    args, window = _segment_case(seed)
    want = ref.swakde_segment_pass_ref(
        *args, window=window, maxb=3, n_levels=6, cap=cap)
    got = ic_k.swakde_segment_pass(
        *args, window=window, maxb=3, n_levels=6, cap=cap,
        block_g=block_g, interpret=True)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("mask_frac", [1.0, 0.7])
def test_sann_table_scatter_matches_ref(seed, mask_frac):
    rng = np.random.default_rng(seed)
    L, NB, cap, E = 4, 13, 6, 70
    tables = rng.integers(-1, 100, (L, NB, cap)).astype(np.int32)
    table_ptr = rng.integers(0, cap, (L, NB)).astype(np.int32)
    s_l = rng.integers(0, L, E).astype(np.int32)
    s_c = rng.integers(0, NB, E).astype(np.int32)
    order = np.lexsort((s_c, s_l))
    s_l, s_c = s_l[order], s_c[order]
    rank = np.zeros(E, np.int32)
    for i in range(1, E):
        same = s_l[i] == s_l[i - 1] and s_c[i] == s_c[i - 1]
        rank[i] = rank[i - 1] + 1 if same else 0
    val = rng.integers(0, 10_000, E).astype(np.int32)
    mask = rng.random(E) < mask_frac
    a = tuple(jnp.asarray(x) for x in
              (tables, table_ptr, s_l, s_c, rank, val, mask))
    want = ref.sann_table_scatter_ref(*a)
    got = ic_k.sann_table_scatter(*a, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_live_blocks_from_sketch_compaction():
    sigs = jnp.asarray([[1, 0, 0], [1, 1, 0], [0, 0, 1], [1, 1, 1]], bool)
    qsig = jnp.asarray([1, 1, 0], bool)
    ids, n_live = sda_k.live_blocks_from_sketch(
        qsig, sigs, kv_len=jnp.int32(4 * 16), block_size=16, min_match=2)
    ids = np.asarray(ids)
    assert int(n_live[0]) == 2
    assert set(ids[:2].tolist()) == {1, 3}
    assert (ids[2:] == -1).all()
