"""Durability subsystem (repro.persist + the engine's recover()):

  * WAL framing: append/replay roundtrip, torn-tail tolerance + truncation,
    segment rotation + compaction;
  * recovery bit-identity: for each sketch service, snapshot + WAL-tail
    ``recover()`` after a simulated crash reproduces the *uninterrupted*
    engine state bit-for-bit — including S-ANN ring-wrap/eviction and the
    SW-AKDE EH clock/expiry state — because replay runs the same
    seq-keyed prepare/commit path the live engine runs;
  * the recover-before-ingest guard on a dirty durability directory;
  * WAL-logged mutations (deletes) replay in apply order.
"""
import numpy as np
import pytest

import jax

from repro import persist
from repro.persist import faults
from repro.persist.wal import WriteAheadLog
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

# Ring-wrap regime: keep prob 64^-0.1 ~ 0.66 over 400 points ~ 264 kept
# > capacity max(64, 4 * 64^0.9) = 168 -> the ring laps and evicts.
_RETR_KW = dict(dim=8, n_max=64, eta=0.1, r=0.4, c=2.0, w=1.0, L=6, k=3,
                bucket_cap=4, ingest_chunk=64)
# Window shorter than the stream: EH buckets expire, the clock state (t,
# per-level timestamps) is load-bearing at recovery.
_KDE_KW = dict(dim=8, L=6, W=32, window=150, eh_eps=0.2, ingest_chunk=50)
_RACE_KW = dict(dim=8, L=6, W=32, ingest_chunk=64)


def _data(n=400, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


def _states_equal(a, b):
    return all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _assert_states_equal(a, b):
    for name, (x, y) in zip(a._fields, zip(a, b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {name!r}")


def _crash_mid_stream(svc, data, fail_after: int):
    """Simulate a crash: the commit path dies after ``fail_after`` commits.
    Every chunk was WAL-logged at enqueue time; the engine's fail-stop
    drops the rest, exactly like a killed process with a flushed WAL."""
    orig = svc._commit
    n_done = [0]

    def bomb(state, prep):
        if n_done[0] >= fail_after:
            raise RuntimeError("simulated crash")
        n_done[0] += 1
        return orig(state, prep)

    svc._commit = bomb
    svc.ingest_async(data)
    with pytest.raises(RuntimeError, match="simulated crash"):
        svc.flush()
    svc.close()


# ---------------------------------------------------------------------------
# WAL unit semantics
# ---------------------------------------------------------------------------

def test_wal_roundtrip_rotation_compaction(tmp_path):
    wal = WriteAheadLog(tmp_path)
    for seq in range(4):
        wal.append([(seq, persist.KIND_CHUNK,
                     {"xs": np.full((2, 3), seq, np.float32)})])
    wal.rotate()
    wal.append([(4, persist.KIND_DELETE,
                 {"x": np.arange(3, dtype=np.float32)})])

    recs = wal.replay()
    assert [r.seq for r in recs] == [0, 1, 2, 3, 4]
    assert recs[4].kind == persist.KIND_DELETE
    np.testing.assert_array_equal(recs[2].arrays["xs"],
                                  np.full((2, 3), 2, np.float32))
    assert [r.seq for r in wal.replay(after=2)] == [3, 4]

    # compaction: seqs 0..3 covered by a snapshot -> sealed segment deleted,
    # the active segment (seq 4) survives.
    assert wal.compact(upto=3) == 1
    assert [r.seq for r in wal.replay()] == [4]
    wal.close()


def test_wal_torn_tail_tolerated_and_truncated(tmp_path):
    wal = WriteAheadLog(tmp_path)
    for seq in range(3):
        wal.append([(seq, persist.KIND_CHUNK,
                     {"xs": np.full((8,), seq, np.float32)})])
    wal.close()
    seg = sorted(tmp_path.glob("wal_*.log"))[-1]
    good = seg.stat().st_size

    # torn final record: half a record's bytes survive the crash
    with open(seg, "ab") as f:
        f.write(b"\x31LWS\xff\xff garbage")
    wal = WriteAheadLog(tmp_path)
    recs = wal.replay()
    assert [r.seq for r in recs] == [0, 1, 2]      # intact prefix only
    wal.truncate_torn_tail()
    assert seg.stat().st_size == good
    wal.append([(3, persist.KIND_CHUNK, {"xs": np.zeros(2, np.float32)})])
    assert [r.seq for r in wal.replay()] == [0, 1, 2, 3]

    # corrupt a *body* byte: crc catches it, replay stops before the record
    data = bytearray(seg.read_bytes())
    data[-1] ^= 0xFF
    seg.write_bytes(bytes(data))
    wal = WriteAheadLog(tmp_path)
    assert [r.seq for r in wal.replay()] == [0, 1, 2]
    wal.close()


# ---------------------------------------------------------------------------
# Crash-recovery bit-identity per service
# ---------------------------------------------------------------------------

def test_retrieval_recovery_bit_identity_ring_wrap(tmp_path):
    data = _data(seed=1)
    ref = RetrievalService(RetrievalConfig(**_RETR_KW))
    ref.ingest(data)
    assert int(ref.state.write_ptr) != int(ref.state.n_stored), \
        "config must exercise ring wrap for this test to bite"

    dur = dict(snapshot_dir=str(tmp_path), snapshot_every=2)
    crash = RetrievalService(RetrievalConfig(**_RETR_KW, **dur,
                                             pipelined=False))
    _crash_mid_stream(crash, data, fail_after=3)

    svc = RetrievalService(RetrievalConfig(**_RETR_KW, **dur))
    replayed = svc.recover()
    n_chunks = -(-len(data) // _RETR_KW["ingest_chunk"])
    assert 0 < replayed < n_chunks, \
        "recovery should start from a snapshot, not replay the whole log"
    _assert_states_equal(svc.state, ref.state)

    # the recovered engine keeps ingesting on the same seq schedule
    more = _data(n=64, seed=2)
    svc.ingest(more)
    ref.ingest(more)
    _assert_states_equal(svc.state, ref.state)
    svc.close()


def test_kde_recovery_bit_identity_eh_clock(tmp_path):
    data = _data(seed=3)
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)
    assert int(ref.state.t) == len(data) > _KDE_KW["window"]

    dur = dict(snapshot_dir=str(tmp_path), snapshot_every=3)
    crash = KDEService(KDEServiceConfig(**_KDE_KW, **dur, pipelined=False))
    _crash_mid_stream(crash, data, fail_after=2)

    svc = KDEService(KDEServiceConfig(**_KDE_KW, **dur))
    svc.recover()
    _assert_states_equal(svc.state, ref.state)      # incl. ts/num/t (clock)
    qs = data[:5] + 0.01
    np.testing.assert_array_equal(svc.query(qs), ref.query(qs))
    svc.close()


def test_race_recovery_bit_identity_with_delete(tmp_path):
    data = _data(seed=4)
    ref = RACEService(RACEServiceConfig(**_RACE_KW))
    svc = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path),
                                        snapshot_every=2))
    for s in (ref, svc):
        s.ingest(data[:200])
        s.delete(data[:3])           # WAL-logged mutation record
        s.ingest(data[200:])
    _assert_states_equal(svc.state, ref.state)
    svc.close()

    # fresh process: snapshot + WAL tail (chunks *and* the delete record)
    rec = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path),
                                        snapshot_every=2))
    rec.recover()
    _assert_states_equal(rec.state, ref.state)
    assert rec.count == ref.count == len(data) - 3
    rec.close()


def test_recovery_after_torn_wal_tail(tmp_path):
    data = _data(n=192, seed=5)
    svc = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    svc.ingest(data)
    svc.close()
    seg = sorted((tmp_path / "wal").glob("wal_*.log"))[-1]
    with open(seg, "ab") as f:                 # crash mid-append
        f.write(b"\x00" * 10)

    ref = RACEService(RACEServiceConfig(**_RACE_KW))
    ref.ingest(data)
    rec = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    rec.recover()                              # tolerates + truncates tail
    _assert_states_equal(rec.state, ref.state)
    rec.ingest(data[:64])                      # appends extend the log
    rec.close()


def test_durable_engine_poisons_ingest_after_commit_failure(tmp_path):
    """Once a durable engine drops WAL-logged chunks (fail-stop), its
    in-memory state no longer tracks the log — continued ingest would let
    snapshot labels drift from WAL seqs.  Further ingest must be refused,
    and recovery on a fresh engine replays *every* accepted chunk (the
    failure was transient, so the WAL is the truth)."""
    data = _data(n=200, seed=8)
    kw = dict(**_KDE_KW, snapshot_dir=str(tmp_path))
    crash = KDEService(KDEServiceConfig(**kw, pipelined=False))
    orig, n_done = crash._commit, [0]

    def bomb(state, prep):
        if n_done[0] >= 1:
            raise RuntimeError("simulated crash")
        n_done[0] += 1
        return orig(state, prep)

    crash._commit = bomb
    crash.ingest_async(data)
    with pytest.raises(RuntimeError, match="simulated crash"):
        crash.flush()
    with pytest.raises(RuntimeError, match="recover"):
        crash.ingest(data)               # poisoned, even though fault gone
    crash.close()

    rec = KDEService(KDEServiceConfig(**kw))
    rec.recover()
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)                 # all logged chunks, incl. the dropped
    _assert_states_equal(rec.state, ref.state)
    rec.close()


def test_dirty_dir_requires_recover(tmp_path):
    data = _data(n=100, seed=6)
    svc = KDEService(KDEServiceConfig(**_KDE_KW, snapshot_dir=str(tmp_path)))
    svc.ingest(data)
    svc.close()

    fresh = KDEService(KDEServiceConfig(**_KDE_KW,
                                        snapshot_dir=str(tmp_path)))
    with pytest.raises(RuntimeError, match="recover"):
        fresh.ingest(data)
    fresh.recover()
    fresh.ingest(data)
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)
    ref.ingest(data)
    _assert_states_equal(fresh.state, ref.state)
    fresh.close()

    # recover() refuses to run on an engine that already ingested
    used = KDEService(KDEServiceConfig(**_KDE_KW))
    used.ingest(data)
    with pytest.raises(RuntimeError, match="DurabilityConfig"):
        used.recover()


def test_failed_mutation_wal_append_poisons(tmp_path):
    """A failed WAL append during a mutation may leave torn bytes mid-log;
    the engine must poison (like the chunk path) instead of letting a
    retry append after the garbage."""
    data = _data(n=100, seed=11)
    svc = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    svc.ingest(data)

    def bad_append(records):
        raise OSError("disk full")

    svc._wal.append = bad_append
    with pytest.raises(OSError, match="disk full"):
        svc.delete(data[:1])
    with pytest.raises(RuntimeError, match="recover"):
        svc.ingest(data)
    svc.close()


def test_prune_never_deletes_newest_and_config_validates(tmp_path):
    """The newest snapshot must survive pruning (its WAL records are
    compacted away), and DurabilityConfig rejects keep_snapshots < 1."""
    for seq in (2, 4, 6):
        persist.snapshot.save(tmp_path, seq, {"x": np.arange(seq)})
    assert persist.snapshot.prune(tmp_path, keep=0) == 2   # clamped to 1
    assert persist.snapshot.latest_seq(tmp_path) == 6
    with pytest.raises(ValueError, match="keep_snapshots"):
        persist.DurabilityConfig(dir=str(tmp_path), keep_snapshots=0)
    with pytest.raises(ValueError, match="snapshot_every"):
        persist.DurabilityConfig(dir=str(tmp_path), snapshot_every=0)


def test_fsync_snapshot_roundtrip(tmp_path):
    """fsync=True snapshots (the power-loss mode that licenses WAL
    compaction) write and restore exactly like flush-only ones."""
    state = {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
             "b": np.ones(5, np.float32)}
    persist.snapshot.save(tmp_path, 7, state, fsync=True)
    back = persist.snapshot.load(tmp_path, 7, state)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]), state[k])


def test_async_snapshot_failure_surfaces(tmp_path, monkeypatch):
    """A failed background snapshot write must not die silently: the next
    wait() re-raises (and via the engine's commit worker, flush() would
    surface it) — otherwise WAL compaction could outrun a durable
    snapshot."""
    from repro.checkpoint import checkpoint as ckpt_mod

    def boom(path, tree, step, fsync=False):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod, "save", boom)
    ck = ckpt_mod.AsyncCheckpointer()
    ck.save(tmp_path / "step_1", {"x": np.zeros(3)}, 1)
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        ck.wait()
    ck.wait()                    # error consumed; checkpointer reusable


def test_failed_mutation_apply_poisons_durable_engine(tmp_path):
    """If a WAL-logged mutation fails to *apply*, the op is on disk but
    not in memory — the engine must refuse further work (poison) so a
    later snapshot can't be labelled as if the op applied; recovery
    replays the logged op."""
    data = _data(n=100, seed=10)
    svc = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    svc.ingest(data)

    def boom(st):
        raise RuntimeError("apply exploded")

    with pytest.raises(RuntimeError, match="apply exploded"):
        svc._durable_mutate(persist.KIND_DELETE,
                            {"xs": data[:1]}, boom)
    with pytest.raises(RuntimeError, match="recover"):
        svc.ingest(data)
    svc.close()

    ref = RACEService(RACEServiceConfig(**_RACE_KW))
    ref.ingest(data)
    ref.delete(data[:1])                  # what the logged record means
    rec = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    rec.recover()                         # applies the logged delete
    _assert_states_equal(rec.state, ref.state)
    rec.close()


def test_mutation_only_workload_still_snapshots(tmp_path):
    """WAL-logged mutations count toward the snapshot cadence: a
    delete-heavy durable engine must keep snapshotting (bounding WAL
    growth and recovery replay), not only on chunk commits."""
    data = _data(n=64, seed=9)
    kw = dict(**_RACE_KW, snapshot_dir=str(tmp_path), snapshot_every=4)
    svc = RACEService(RACEServiceConfig(**kw))
    svc.ingest(data)                      # 1 chunk
    for i in range(12):                   # 12 mutation records, no chunks
        svc.delete(data[i:i + 1])
    svc.close()
    snaps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert snaps and max(snaps) > 4, f"no mutation-driven snapshot: {snaps}"

    ref = RACEService(RACEServiceConfig(**_RACE_KW))
    ref.ingest(data)
    for i in range(12):
        ref.delete(data[i:i + 1])
    rec = RACEService(RACEServiceConfig(**kw))
    replayed = rec.recover()
    assert replayed < 13                  # tail only, not the whole log
    _assert_states_equal(rec.state, ref.state)
    rec.close()


def test_wal_iter_replay_is_lazy_and_equals_replay(tmp_path):
    """`iter_replay` is the streaming form of `replay`: same records, one
    at a time (recover() uses it so a long tail never materialises)."""
    wal = WriteAheadLog(tmp_path)
    for seq in range(5):
        wal.append([(seq, persist.KIND_CHUNK,
                     {"xs": np.full((4,), seq, np.float32)})])
    it = wal.iter_replay(after=1)
    assert iter(it) is it                        # generator, not a list
    first = next(it)
    assert first.seq == 2
    rest = list(it)
    assert [r.seq for r in rest] == [3, 4]
    assert [r.seq for r in wal.replay(after=1)] == [2, 3, 4]
    wal.close()


# ---------------------------------------------------------------------------
# Fault-site property: crash anywhere + recover() = bit-identical
# ---------------------------------------------------------------------------

# (site, mode, hit): every named durability fault site, each killed by the
# deterministic injection harness at a point where it is actually reached
# (KDE: chunk=50, 300 rows -> 6 chunks; snapshot_every=2 -> snapshots at
# commits 2/4/6, so rotate/compact/save all fire).
_FAULT_POINTS = [
    ("wal.append", "crash", 3),
    ("wal.append", "torn_tail", 3),
    ("wal.rotate", "crash", 1),
    ("wal.compact", "crash", 1),
    ("snapshot.save", "crash", 2),
    ("engine.commit", "crash", 3),
]


@pytest.mark.parametrize("site,mode,hit", _FAULT_POINTS,
                         ids=[f"{s}-{m}" for s, m, _ in _FAULT_POINTS])
def test_every_fault_site_crash_recovers_bit_identical(tmp_path, site,
                                                       mode, hit):
    """The recovery property, quantified over the fault surface: no matter
    WHICH durability site dies (WAL append — clean or torn —, rotation,
    compaction, snapshot write, commit), a fresh engine's `recover()`
    reproduces exactly the accepted prefix, and resumed ingest converges
    bit-identically with the never-crashed run."""
    data = _data(n=300, seed=13)
    kw = dict(**_KDE_KW, snapshot_dir=str(tmp_path), snapshot_every=2)
    svc = KDEService(KDEServiceConfig(**kw, pipelined=False))
    plan = persist.FaultPlan([persist.FaultSpec(site=site, mode=mode,
                                                hit=hit)])
    with faults.installed(plan):
        try:
            svc.ingest(data)
        except BaseException:
            pass
    assert plan.hits.get(site), f"fault site {site!r} was never exercised"
    assert plan.fired, "the fault never fired"
    svc.close()

    rec = KDEService(KDEServiceConfig(**kw))
    rec.recover()
    accepted = rec._committed_seq          # ops == chunks (no mutations)
    chunk = _KDE_KW["ingest_chunk"]
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data[:accepted * chunk])
    _assert_states_equal(rec.state, ref.state)

    # resumed ingest stays on the same seq schedule as the unbroken run
    rec.ingest(data[accepted * chunk:])
    ref.ingest(data[accepted * chunk:])
    more = _data(n=100, seed=14)
    rec.ingest(more)
    ref.ingest(more)
    _assert_states_equal(rec.state, ref.state)
    qs = data[:5] + 0.01
    np.testing.assert_array_equal(rec.query(qs), ref.query(qs))
    rec.close()


def test_transient_fault_rejects_without_poisoning(tmp_path):
    """A transient injected IO error on the first chunk of an ingest call
    accepted nothing: the call fails cleanly, the engine stays LIVE, and
    an in-place retry lands the identical state (the cluster's backoff
    retry path relies on exactly this)."""
    data = _data(n=100, seed=15)
    svc = RACEService(RACEServiceConfig(**_RACE_KW,
                                        snapshot_dir=str(tmp_path)))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="wal.append", mode="io_error", transient=True)])
    with faults.installed(plan):
        with pytest.raises(OSError):
            svc.ingest(data)
        svc.ingest(data)                   # fault spent: retry succeeds
    ref = RACEService(RACEServiceConfig(**_RACE_KW))
    ref.ingest(data)
    _assert_states_equal(svc.state, ref.state)
    svc.close()


def test_snapshot_cadence_compacts_wal_and_prunes(tmp_path):
    data = _data(n=8 * 50, seed=7)
    kw = dict(**_KDE_KW, snapshot_dir=str(tmp_path), snapshot_every=2)
    svc = KDEService(KDEServiceConfig(**kw))
    svc.ingest(data)          # 8 chunks -> snapshots at 2, 4, 6, 8
    svc.ingest(data)          # + 8 more
    svc.close()
    snaps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert len(snaps) <= 3, f"pruning failed: {snaps}"   # keep=2 (+inflight)
    segs = list((tmp_path / "wal").glob("wal_*.log"))
    assert len(segs) <= 3, f"compaction failed: {segs}"

    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)
    ref.ingest(data)
    rec = KDEService(KDEServiceConfig(**kw))
    rec.recover()
    _assert_states_equal(rec.state, ref.state)
    rec.close()
